//! Property-based tests (deterministic-PRNG harness standing in for
//! proptest, which is unavailable offline): random DFGs through the
//! mapper, random traces through the cache model, random profit matrices
//! through Algorithm 1.

use cgra_mem::mem::{AccessKind, AccessOutcome, Cache, CacheConfig};
use cgra_mem::reconfig::max_profit;
use cgra_mem::sim::{AluOp, Dfg, DfgBuilder, Geometry, Mapper, Op};
use cgra_mem::util::Rng;

/// Generate a random, valid DFG: a few constants/index nodes, random ALU
/// layers, loads with computed addresses, one store.
fn random_dfg(rng: &mut Rng, ports: usize) -> Dfg {
    let mut b = DfgBuilder::new("prop");
    let i = b.iter_idx();
    let mut pool = vec![i];
    for _ in 0..rng.gen_range(1, 4) {
        let c = b.konst(rng.next_u64() as u32 & 0xff);
        pool.push(c);
    }
    let n_alu = rng.gen_range(1, 8) as usize;
    for _ in 0..n_alu {
        let ops = [AluOp::Add, AluOp::Sub, AluOp::And, AluOp::Or, AluOp::Xor, AluOp::Shl];
        let op = ops[(rng.next_u64() % ops.len() as u64) as usize];
        let a = pool[(rng.next_u64() % pool.len() as u64) as usize];
        let c = pool[(rng.next_u64() % pool.len() as u64) as usize];
        pool.push(b.alu(op, a, c));
    }
    let n_loads = rng.gen_range(1, 4) as usize;
    for k in 0..n_loads {
        let idx = pool[(rng.next_u64() % pool.len() as u64) as usize];
        let port = k % ports;
        let v = b.array_load(port, 0x1000 * (k as u32 + 1), idx);
        pool.push(v);
    }
    let data = pool[(rng.next_u64() % pool.len() as u64) as usize];
    let addr_idx = pool[(rng.next_u64() % pool.len() as u64) as usize];
    b.array_store(rng.gen_range(0, ports as u64) as usize, 0x40_000, addr_idx, data);
    b.finish()
}

/// Check a mapping against all scheduling constraints.
fn assert_valid(dfg: &Dfg, g: &Geometry, m: &cgra_mem::sim::Mapping) {
    let ii = m.ii;
    let mut pe_slots = std::collections::HashSet::new();
    let mut port_slots = std::collections::HashSet::new();
    for (id, &(pe, t)) in m.place.iter().enumerate() {
        assert!(pe < g.num_pes());
        assert!(pe_slots.insert((pe, t % ii)), "pe slot conflict at node {id}");
        match dfg.nodes[id].op {
            Op::Load(s) | Op::Store(s) => {
                assert!(g.is_mem_pe(pe), "mem node off border");
                assert_eq!(g.port_of_pe(pe), s.port, "wrong port");
                assert!(port_slots.insert((s.port, t % ii)), "port conflict");
            }
            _ => {}
        }
        for e in &dfg.nodes[id].inputs {
            let (_, ts) = m.place[e.src];
            assert!(t + e.dist * ii >= ts + dfg.latency(e.src), "dependence violated");
        }
    }
    for d in &dfg.deps {
        let (_, ts) = m.place[d.src];
        let (_, td) = m.place[d.dst];
        assert!(td + d.dist * ii >= ts + 1, "mem dep violated");
    }
}

/// Every registered workload family builds at small scale and validates
/// bit-for-bit against its golden executor under the Ideal backend (the
/// backend with no timing noise: any mismatch is a semantic bug in the
/// family's DFG or golden, not a memory artifact).
#[test]
fn prop_every_family_validates_against_golden_under_ideal() {
    use cgra_mem::exp::{Params, ScenarioSpec, WorkloadRegistry};
    use cgra_mem::mem::{IdealConfig, MemoryModelSpec};
    use cgra_mem::sim::{CgraConfig, ExecMode};
    use cgra_mem::workloads::run_workload_model;
    let reg = WorkloadRegistry::builtin();
    let ideal = MemoryModelSpec::Ideal(IdealConfig::with_ports(2));
    let families = reg.family_names();
    assert!(families.len() >= 10, "expected the full family set, got {families:?}");
    assert!(families.iter().any(|f| f == "phased"), "phased family registered");
    for fam in families {
        let s = ScenarioSpec::family(fam.as_str(), Params::new().set_str("scale", "small"));
        let wl = reg.resolve(&s).unwrap_or_else(|e| panic!("{e}"));
        let run =
            run_workload_model(wl.as_ref(), &ideal, CgraConfig::hycube_4x4(ExecMode::Normal));
        assert!(run.output_ok, "family {fam} diverged from golden under Ideal");
    }
}

/// Cell identity is invariant under spelling: JSON key order (of both
/// system objects and scenario params), display names, and
/// preset-vs-equivalent-family-params spellings all hash to the same
/// [`CellKey`] — while any change to a measured quantity changes it.
#[test]
fn prop_cell_key_invariant_under_key_order_and_preset_spelling() {
    use cgra_mem::exp::{CellKey, Json, Params, ScenarioSpec, SystemSpec, WorkloadRegistry};
    let reg = WorkloadRegistry::builtin();
    let key = |scen: &ScenarioSpec, sys: &SystemSpec, rep: u32| {
        CellKey::compute(&reg, scen, sys, rep).unwrap()
    };

    // System JSON: same overrides, shuffled key order, different name.
    let sys_a = SystemSpec::from_json(
        &Json::parse(r#"{"base": "Cache+SPM", "l1_ways": 2, "mshr": 4, "spm_bytes": 1024}"#)
            .unwrap(),
    )
    .unwrap();
    let sys_b = SystemSpec::from_json(
        &Json::parse(
            r#"{"spm_bytes": 1024, "mshr": 4, "name": "renamed", "base": "Cache+SPM",
                "l1_ways": 2}"#,
        )
        .unwrap(),
    )
    .unwrap();
    let scen = ScenarioSpec::preset("aggregate/tiny");
    assert_eq!(key(&scen, &sys_a, 0), key(&scen, &sys_b, 0));

    // Scenario params: every insertion order of the same bag is one cell.
    let mut rng = Rng::new(99);
    let reference = {
        let p = Params::new().set_u64("dim", 24).set_u64("seed", 7).set_str("order", "random");
        key(&ScenarioSpec::family("mesh", p), &sys_a, 0)
    };
    for _ in 0..20 {
        // Random insertion order, random display name: same key.
        let mut order: Vec<usize> = (0..3).collect();
        for i in (1..order.len()).rev() {
            order.swap(i, rng.gen_range(0, (i + 1) as u64) as usize);
        }
        let mut p = Params::new();
        for &i in &order {
            p = match i {
                0 => p.set_u64("dim", 24),
                1 => p.set_u64("seed", 7),
                _ => p.set_str("order", "random"),
            };
        }
        let scen = ScenarioSpec::family("mesh", p).named(format!("label-{:x}", rng.next_u64()));
        assert_eq!(key(&scen, &sys_a, 0), reference);
    }

    // Preset names and their stored (family, params) identity collide.
    for (preset, family, params) in [
        ("small/mesh", "mesh", Params::new().set_str("scale", "small")),
        ("aggregate/cora", "aggregate", Params::new().set_str("dataset", "cora")),
        (
            "small/join_probe",
            "join",
            Params::new().set_str("phase", "probe").set_str("scale", "small"),
        ),
    ] {
        assert_eq!(
            key(&ScenarioSpec::preset(preset), &sys_a, 0),
            key(&ScenarioSpec::family(family, params), &sys_a, 0),
            "{preset} must equal its family spelling"
        );
    }

    // Distinct identities stay distinct.
    assert_ne!(key(&scen, &sys_a, 0), key(&scen, &sys_a, 1));
    let other = SystemSpec::from_json(
        &Json::parse(r#"{"base": "Cache+SPM", "l1_ways": 4, "mshr": 4, "spm_bytes": 1024}"#)
            .unwrap(),
    )
    .unwrap();
    assert_ne!(key(&scen, &sys_a, 0), key(&scen, &other, 0));
    assert_ne!(
        key(&ScenarioSpec::preset("small/mesh"), &sys_a, 0),
        key(&ScenarioSpec::preset("mesh"), &sys_a, 0),
        "small and paper scale are different cells"
    );
}

/// Cluster serving is deterministic: the same (mix, cluster) cells
/// measured through fresh engines — and through engines with different
/// worker counts — produce byte-identical reports. The interleaver steps
/// arrays by minimum cycle (ties by slot index) and the mix is seeded, so
/// no wall-clock or thread-schedule state can leak into a measurement.
#[test]
fn prop_cluster_serving_is_deterministic_across_runs_and_worker_counts() {
    use cgra_mem::exp::{Engine, ExperimentSpec, ScenarioSpec, SystemSpec};
    let spec = || {
        ExperimentSpec::new("determinism")
            .workload(ScenarioSpec::mix(8, 0.7, 42))
            .systems([SystemSpec::cluster_runahead(2), SystemSpec::cluster_locality()])
    };
    let render = |threads: usize| Engine::new(threads).run(&spec()).to_json().render_pretty();
    let a = render(1);
    let b = render(1);
    let c = render(4);
    assert_eq!(a, b, "same run twice must reproduce byte-identically");
    assert_eq!(a, c, "worker count must not leak into cluster measurements");
}

/// Force a system's CGRA (solo or cluster) onto one simulation core.
/// Cpu models have no core knob — they are untouched by design.
fn with_core(mut sys: cgra_mem::exp::SystemSpec, core: cgra_mem::sim::SimCore) -> cgra_mem::exp::SystemSpec {
    use cgra_mem::exp::ExecModel;
    match &mut sys.exec {
        ExecModel::Cgra { cgra, .. } | ExecModel::Cluster { cgra, .. } => cgra.core = core,
        ExecModel::Cpu(_) => {}
    }
    sys
}

/// The tentpole proof: the event-driven core (timewheel completions +
/// stall fast-forwarding) is *byte-identical* to the reference +1-stepping
/// core — same Measurements, same rendered report — across the memory
/// backends with different stall shapes: SPM-only (structural MSHR=1
/// stalls), Cache+SPM (plain miss stalls), Runahead (dead cycles, timeout
/// waits), and the banked-DRAM channel (bank/row-dependent latencies).
#[test]
fn prop_event_core_report_is_byte_identical_to_reference_core() {
    use cgra_mem::exp::{Engine, ExperimentSpec, ScenarioSpec, SystemSpec};
    use cgra_mem::sim::SimCore;
    let render = |core: SimCore| {
        let systems = [
            SystemSpec::spm_only(),
            SystemSpec::cache_spm(),
            SystemSpec::runahead(),
            SystemSpec::banked_dram(),
        ]
        .map(|s| with_core(s, core));
        let spec = ExperimentSpec::new("core-equivalence")
            .workload(ScenarioSpec::preset("aggregate/tiny"))
            .workload(ScenarioSpec::preset("small/phased"))
            .workload(ScenarioSpec::preset("small/join_probe"))
            .systems(systems);
        Engine::new(1).run(&spec).to_json().render_pretty()
    };
    assert_eq!(
        render(SimCore::Event),
        render(SimCore::Reference),
        "event core must reproduce the reference core byte-for-byte"
    );
}

/// Replay fidelity (the trace engine's core contract): feeding a recorded
/// stream back through the *same* memory configuration reproduces the
/// live run's memory counters and timing exactly — across backend shapes
/// (plain hierarchy, banked DRAM, runahead + online reconfig) and kernel
/// classes (gather, hash-join probe, phase-alternating gather).
#[test]
fn prop_replay_through_same_config_reproduces_live_counters_exactly() {
    use cgra_mem::exp::{
        measure_replay, measure_spec_captured, ExecModel, ScenarioSpec, SystemSpec,
        WorkloadRegistry,
    };
    use cgra_mem::sim::ReconfigPolicy;
    let reg = WorkloadRegistry::builtin();
    let mut ra_reconfig = SystemSpec::runahead().named("Runahead+Reconfig");
    match &mut ra_reconfig.exec {
        ExecModel::Cgra { cgra, .. } => cgra.reconfig = ReconfigPolicy::online(),
        _ => unreachable!("runahead is a solo CGRA system"),
    }
    let systems = [SystemSpec::cache_spm(), SystemSpec::banked_dram(), ra_reconfig];
    for kernel in ["aggregate/tiny", "small/join_probe", "small/phased"] {
        let wl = reg.resolve(&ScenarioSpec::preset(kernel)).unwrap();
        for sys in &systems {
            let ctx = format!("{kernel} on {}", sys.name);
            let (live, cap) = measure_spec_captured(wl.as_ref(), &sys.clone().with_capture());
            let trace = cap.expect("capture-enabled run records a trace");
            let (mem, cgra) = match &sys.exec {
                ExecModel::Cgra { mem, cgra } => (mem.clone(), *cgra),
                _ => unreachable!("all three sources are solo CGRA systems"),
            };
            let rspec = SystemSpec::replay_of("replayed", sys.clone(), mem, cgra);
            let (rm, out) =
                measure_replay(kernel, &rspec, &trace).unwrap_or_else(|e| panic!("{ctx}: {e}"));
            assert!(out.events_replayed > 0, "{ctx}: empty replay");
            for (col, replayed, lived) in [
                ("cycles", rm.cycles, live.cycles),
                ("stall_cycles", rm.stall_cycles, live.stall_cycles),
                ("spm_accesses", rm.spm_accesses, live.spm_accesses),
                ("l1_accesses", rm.l1_accesses, live.l1_accesses),
                ("l1_hits", rm.l1_hits, live.l1_hits),
                ("l2_accesses", rm.l2_accesses, live.l2_accesses),
                ("dram_accesses", rm.dram_accesses, live.dram_accesses),
                ("dram_row_hits", rm.dram_row_hits, live.dram_row_hits),
                ("dram_row_conflicts", rm.dram_row_conflicts, live.dram_row_conflicts),
                ("prefetch_used", rm.prefetch_used, live.prefetch_used),
                ("prefetch_evicted", rm.prefetch_evicted, live.prefetch_evicted),
                ("prefetch_useless", rm.prefetch_useless, live.prefetch_useless),
                ("runahead_entries", rm.runahead_entries, live.runahead_entries),
                ("reconfig_applies", rm.reconfig_applies, live.reconfig_applies),
                ("reconfig_ways_moved", rm.reconfig_ways_moved, live.reconfig_ways_moved),
            ] {
                assert_eq!(replayed, lived, "{col} diverged: {ctx}");
            }
            // Derived floats come from identical integers via identical
            // formulas, so bitwise equality is the right bar.
            assert_eq!(rm.time_us, live.time_us, "time_us diverged: {ctx}");
            assert_eq!(rm.utilization, live.utilization, "utilization diverged: {ctx}");
            assert_eq!(rm.coverage, live.coverage, "coverage diverged: {ctx}");
        }
    }
}

/// Cluster clamp proof: on a skewed 24-job mix, serving results
/// (makespan, per-job records, per-array stats, channel row/xarray
/// counters — everything in the rendered report) are byte-identical
/// across worker counts AND across simulation cores. The fast-forward
/// clamp pins every jump below the minimum cycle of the other live
/// slots, so shared-L2/DRAM contention ordering cannot drift.
#[test]
fn prop_cluster_results_identical_across_cores_and_workers() {
    use cgra_mem::exp::{Engine, ExperimentSpec, ScenarioSpec, SystemSpec};
    use cgra_mem::sim::SimCore;
    let render = |threads: usize, core: SimCore| {
        let systems =
            [SystemSpec::cluster_runahead(2), SystemSpec::cluster_locality()].map(|s| with_core(s, core));
        let spec = ExperimentSpec::new("cluster-core-equivalence")
            .workload(ScenarioSpec::mix(24, 0.8, 11))
            .systems(systems);
        Engine::new(threads).run(&spec).to_json().render_pretty()
    };
    let reference = render(1, SimCore::Reference);
    assert_eq!(render(1, SimCore::Event), reference, "event core drifted on the cluster mix");
    assert_eq!(render(4, SimCore::Event), reference, "worker count leaked into cluster results");
}

#[test]
fn prop_mapper_produces_valid_schedules() {
    let mut rng = Rng::new(2024);
    let geoms = [
        Geometry { rows: 4, cols: 4, ports: 2, hop_budget: 3 },
        Geometry { rows: 8, cols: 8, ports: 4, hop_budget: 3 },
    ];
    let mut mapped = 0;
    for trial in 0..200 {
        let g = geoms[trial % geoms.len()];
        let dfg = random_dfg(&mut rng, g.ports);
        if let Ok(m) = Mapper::new(g).map(&dfg) {
            assert_valid(&dfg, &g, &m);
            assert!(m.ii >= Mapper::new(g).res_mii(&dfg), "II below resource bound");
            mapped += 1;
        }
    }
    assert!(mapped > 150, "mapper should succeed on most random DFGs ({mapped}/200)");
}

#[test]
fn prop_cache_hit_iff_recently_filled() {
    // Invariant: after fill(addr), probe(addr) hits until ≥`ways` distinct
    // conflicting fills to the same virtual set occur.
    let mut rng = Rng::new(7);
    for _ in 0..100 {
        let ways = 1 + (rng.next_u64() % 4) as usize;
        let sets = 1usize << rng.gen_range(1, 5);
        let cfg = CacheConfig { sets, ways, line_bytes: 16, vline_shift: 0 };
        let mut c = Cache::new(cfg, 0);
        let target = (rng.next_u64() as u32) & 0xffff0;
        c.fill(target, false, 0);
        assert_eq!(c.probe(target), AccessOutcome::Hit);
        // Fewer than `ways` conflicting fills cannot evict the target
        // (LRU prefers invalid ways first).
        let vset_stride = (sets as u32) * 16;
        for k in 1..ways as u32 {
            c.fill(target + k * vset_stride, false, 0);
        }
        assert_eq!(c.probe(target), AccessOutcome::Hit, "ways={ways} sets={sets}");
    }
}

#[test]
fn prop_cache_stats_are_consistent() {
    let mut rng = Rng::new(13);
    for _ in 0..50 {
        let cfg = CacheConfig { sets: 8, ways: 2, line_bytes: 32, vline_shift: 0 };
        let mut c = Cache::new(cfg, 0);
        let n = 200 + (rng.next_u64() % 200) as u64;
        for _ in 0..n {
            let addr = (rng.next_u64() as u32) % 8192;
            let kind = if rng.next_u64() % 4 == 0 { AccessKind::Write } else { AccessKind::Read };
            if c.access(addr, kind) == AccessOutcome::Miss {
                c.fill(addr, false, 0);
            }
        }
        assert_eq!(c.stats.hits + c.stats.misses, c.stats.accesses());
        assert_eq!(c.stats.accesses(), n);
        assert!(c.stats.fills <= c.stats.misses);
    }
}

#[test]
fn prop_dp_allocator_never_exceeds_budget_and_is_monotone() {
    let mut rng = Rng::new(31);
    for _ in 0..100 {
        let n = 1 + (rng.next_u64() % 4) as usize;
        let t = (rng.next_u64() % 12) as usize;
        // Monotone profits (hit rate never decreases with more ways).
        let h: Vec<Vec<f64>> = (0..n)
            .map(|_| {
                let mut acc = -(rng.gen_f32() as f64) - 0.1;
                (0..=t)
                    .map(|_| {
                        acc += rng.gen_f32() as f64 * 0.2;
                        acc
                    })
                    .collect()
            })
            .collect();
        let (profit, alloc) = max_profit(&h, t);
        assert!(alloc.iter().sum::<usize>() <= t);
        let achieved: f64 = alloc.iter().enumerate().map(|(i, &k)| h[i][k]).sum();
        assert!((achieved - profit).abs() < 1e-9);
        if t > 0 {
            // With strictly monotone profits the optimum uses the budget.
            let (p_small, _) = max_profit(&h, t - 1);
            assert!(profit >= p_small - 1e-12, "monotone in budget");
        }
    }
}

#[test]
fn prop_virtual_line_partitions_address_space() {
    // Every address maps into exactly one virtual line; block_addr is
    // idempotent and alignment-consistent.
    let mut rng = Rng::new(47);
    for m in 0..3u8 {
        let cfg = CacheConfig { sets: 16, ways: 2, line_bytes: 32, vline_shift: m };
        let c = Cache::new(cfg, 0);
        for _ in 0..200 {
            let a = rng.next_u64() as u32 & 0xf_ffff;
            let b = c.block_addr(a);
            assert_eq!(b % cfg.vline_bytes(), 0);
            assert!(a >= b && a - b < cfg.vline_bytes());
            assert_eq!(c.block_addr(b), b);
        }
    }
}
