//! Cross-module integration tests: full workload runs across systems and
//! modes, output validation everywhere, and paper-shape assertions.

use cgra_mem::coordinator::{measure, reconfig_experiment, System};
use cgra_mem::mem::SubsystemConfig;
use cgra_mem::sim::{CgraConfig, ExecMode};
use cgra_mem::workloads::{run_workload, small_suite, GcnAggregate, GraphSpec};

/// Every kernel in the (reduced-size) suite computes correct output on
/// every CGRA system in both execution modes.
#[test]
fn small_suite_correct_on_all_cgra_systems() {
    for wl in small_suite() {
        for (sys, mode) in [
            (SubsystemConfig::spm_only(2, 4096), ExecMode::Normal),
            (SubsystemConfig::paper_base(), ExecMode::Normal),
            (SubsystemConfig::paper_base(), ExecMode::Runahead),
        ] {
            let run = run_workload(wl.as_ref(), sys, CgraConfig::hycube_4x4(mode));
            assert!(run.output_ok, "{} {:?} diverged", wl.name(), mode);
        }
    }
}

/// The 8×8 geometry must also validate (4 virtual SPMs).
#[test]
fn small_suite_correct_on_8x8() {
    for wl in small_suite() {
        let run = run_workload(
            wl.as_ref(),
            SubsystemConfig::paper_reconfig(),
            CgraConfig::hycube_8x8(ExecMode::Runahead),
        );
        assert!(run.output_ok, "{} diverged on 8x8", wl.name());
    }
}

/// Runahead never changes results and never loses cycles catastrophically.
#[test]
fn runahead_is_safe_and_effective_on_small_suite() {
    for wl in small_suite() {
        let n = run_workload(
            wl.as_ref(),
            SubsystemConfig::paper_base(),
            CgraConfig::hycube_4x4(ExecMode::Normal),
        );
        let r = run_workload(
            wl.as_ref(),
            SubsystemConfig::paper_base(),
            CgraConfig::hycube_4x4(ExecMode::Runahead),
        );
        assert!(r.output_ok && n.output_ok, "{}", wl.name());
        assert!(
            r.result.cycles <= n.result.cycles * 11 / 10,
            "{}: runahead {} vs normal {}",
            wl.name(),
            r.result.cycles,
            n.result.cycles
        );
    }
}

/// Determinism: identical runs give identical cycle counts and outputs.
#[test]
fn simulation_is_deterministic() {
    let wl = GcnAggregate::new(GraphSpec::tiny());
    let a = run_workload(&wl, SubsystemConfig::paper_base(), CgraConfig::hycube_4x4(ExecMode::Runahead));
    let b = run_workload(&wl, SubsystemConfig::paper_base(), CgraConfig::hycube_4x4(ExecMode::Runahead));
    assert_eq!(a.result.cycles, b.result.cycles);
    assert_eq!(a.result.mem.prefetches_issued, b.result.mem.prefetches_issued);
}

/// Fig 11a ordering holds on the tiny kernel for the baselines too.
#[test]
fn baselines_measure_and_validate() {
    let wl = GcnAggregate::new(GraphSpec::tiny());
    let a72 = measure(&wl, System::A72);
    let simd = measure(&wl, System::Simd);
    assert!(simd.time_us < a72.time_us, "SIMD must beat scalar");
}

/// The reconfiguration loop preserves correctness on every small kernel.
#[test]
fn reconfig_loop_preserves_correctness() {
    for wl in small_suite().into_iter().take(4) {
        let out = reconfig_experiment(wl.as_ref(), ExecMode::Normal, 2048);
        assert!(out.output_ok, "{}", wl.name());
    }
}

/// MSHR-starved configurations still complete and validate (structural
/// stall path).
#[test]
fn mshr_starved_system_still_correct() {
    let mut cfg = SubsystemConfig::paper_base();
    cfg.mshr_entries = 1;
    cfg.store_buffer_entries = 1;
    for wl in small_suite().into_iter().take(3) {
        for mode in [ExecMode::Normal, ExecMode::Runahead] {
            let run = run_workload(wl.as_ref(), cfg, CgraConfig::hycube_4x4(mode));
            assert!(run.output_ok, "{} {:?}", wl.name(), mode);
        }
    }
}

/// Tiny single-entry caches (worst-case thrash) still validate.
#[test]
fn degenerate_cache_geometry_still_correct() {
    let mut cfg = SubsystemConfig::paper_base();
    cfg.l1 = cgra_mem::mem::CacheConfig { sets: 1, ways: 1, line_bytes: 16, vline_shift: 0 };
    for wl in small_suite().into_iter().take(3) {
        let run = run_workload(wl.as_ref(), cfg, CgraConfig::hycube_4x4(ExecMode::Runahead));
        assert!(run.output_ok, "{}", wl.name());
    }
}

/// Acceptance: the fig11a five-system campaign reproduces through the new
/// Engine/ExperimentSpec API with the paper's system ordering
/// SPM-starved < Cache+SPM < Runahead (execution time, lower is faster).
/// Restricted to the tiny graph so the test stays fast; the full-size
/// campaign is `repro figure fig11a`. The tiny graph fits the 133 KB SPM
/// entirely, so the SPM-only slot is swapped for a capacity-starved SPM,
/// as in Fig 2.
#[test]
fn engine_reproduces_fig11a_system_ordering() {
    use cgra_mem::exp::{Engine, ExperimentSpec, SystemSpec};
    let starved = SystemSpec::spm_starved(4096);
    let starved_name = starved.name.clone();
    let spec = ExperimentSpec::fig11a()
        .workloads(["aggregate/tiny"])
        .replace_system("SPM-only", starved);
    let engine = Engine::new(2);
    let report = engine.run(&spec);
    assert_eq!(report.measurements.len(), 5);
    assert!(report.measurements.iter().all(|m| m.output_ok));
    let t = |sys: &str| report.time_of("aggregate/tiny", sys).unwrap();
    assert!(t(&starved_name) > t("Cache+SPM"), "SPM-starved must be slowest CGRA");
    assert!(t("Cache+SPM") > t("Runahead"), "runahead must win");
    // Same engine pool serves a follow-up spec (persistent workers).
    let again = engine.run(&ExperimentSpec::new("again")
        .workload("aggregate/tiny")
        .system(SystemSpec::runahead()));
    assert_eq!(again.cycles_of("aggregate/tiny", "Runahead"),
               report.cycles_of("aggregate/tiny", "Runahead"));
}

/// A JSON sweep spec (the `repro sweep` path) round-trips end to end:
/// parse spec → run → emit report → parse report.
#[test]
fn json_sweep_spec_runs_and_report_round_trips() {
    use cgra_mem::exp::{Engine, ExperimentSpec, Json, Report};
    let text = r#"{
        "name": "it-sweep",
        "workloads": ["aggregate/tiny"],
        "systems": [
            {"base": "Cache+SPM"},
            {"base": "Cache+SPM", "name": "Cache+SPM 2-way", "l1_ways": 2},
            {"base": "Runahead", "name": "Runahead-8x8", "geometry": "8x8"}
        ]
    }"#;
    let spec = ExperimentSpec::from_json(&Json::parse(text).unwrap()).unwrap();
    let report = Engine::new(2).run(&spec);
    assert_eq!(report.systems, vec!["Cache+SPM", "Cache+SPM 2-way", "Runahead-8x8"]);
    assert!(report.measurements.iter().all(|m| m.output_ok));
    let back = Report::from_json(&Json::parse(&report.to_json().render_pretty()).unwrap()).unwrap();
    assert_eq!(back, report);
}
