//! Cross-module integration tests: full workload runs across systems and
//! modes, output validation everywhere, and paper-shape assertions.

use cgra_mem::exp::{builtin_systems, measure_spec, SystemSpec};
use cgra_mem::mem::{BankedDramConfig, DramModelKind, MemoryModelSpec, SubsystemConfig};
use cgra_mem::sim::{CgraConfig, ExecMode, ReconfigMode, ReconfigPolicy};
use cgra_mem::workloads::{
    run_workload, run_workload_model, small_suite, GcnAggregate, GraphSpec, HashJoin, MeshOrder,
    MeshSpmv, PhasedGather, Workload,
};

/// Every kernel in the (reduced-size) suite computes correct output on
/// every CGRA system in both execution modes.
#[test]
fn small_suite_correct_on_all_cgra_systems() {
    for wl in small_suite() {
        for (sys, mode) in [
            (SubsystemConfig::spm_only(2, 4096), ExecMode::Normal),
            (SubsystemConfig::paper_base(), ExecMode::Normal),
            (SubsystemConfig::paper_base(), ExecMode::Runahead),
        ] {
            let run = run_workload(wl.as_ref(), sys, CgraConfig::hycube_4x4(mode));
            assert!(run.output_ok, "{} {:?} diverged", wl.name(), mode);
        }
    }
}

/// The 8×8 geometry must also validate (4 virtual SPMs).
#[test]
fn small_suite_correct_on_8x8() {
    for wl in small_suite() {
        let run = run_workload(
            wl.as_ref(),
            SubsystemConfig::paper_reconfig(),
            CgraConfig::hycube_8x8(ExecMode::Runahead),
        );
        assert!(run.output_ok, "{} diverged on 8x8", wl.name());
    }
}

/// Runahead never changes results and never loses cycles catastrophically.
#[test]
fn runahead_is_safe_and_effective_on_small_suite() {
    for wl in small_suite() {
        let n = run_workload(
            wl.as_ref(),
            SubsystemConfig::paper_base(),
            CgraConfig::hycube_4x4(ExecMode::Normal),
        );
        let r = run_workload(
            wl.as_ref(),
            SubsystemConfig::paper_base(),
            CgraConfig::hycube_4x4(ExecMode::Runahead),
        );
        assert!(r.output_ok && n.output_ok, "{}", wl.name());
        assert!(
            r.result.cycles <= n.result.cycles * 11 / 10,
            "{}: runahead {} vs normal {}",
            wl.name(),
            r.result.cycles,
            n.result.cycles
        );
    }
}

/// Determinism: identical runs give identical cycle counts and outputs.
#[test]
fn simulation_is_deterministic() {
    let wl = GcnAggregate::new(GraphSpec::tiny());
    let a = run_workload(&wl, SubsystemConfig::paper_base(), CgraConfig::hycube_4x4(ExecMode::Runahead));
    let b = run_workload(&wl, SubsystemConfig::paper_base(), CgraConfig::hycube_4x4(ExecMode::Runahead));
    assert_eq!(a.result.cycles, b.result.cycles);
    assert_eq!(a.result.mem.prefetches_issued, b.result.mem.prefetches_issued);
}

/// Fig 11a ordering holds on the tiny kernel for the baselines too.
#[test]
fn baselines_measure_and_validate() {
    let wl = GcnAggregate::new(GraphSpec::tiny());
    let a72 = measure_spec(&wl, &SystemSpec::a72());
    let simd = measure_spec(&wl, &SystemSpec::simd());
    assert!(simd.time_us < a72.time_us, "SIMD must beat scalar");
}

/// Every named system (paper five + the extra memory backends + the
/// cluster configurations) measures the tiny GCN kernel with a validated
/// output (the old coordinator enum walk, now over the data-driven
/// registry; cluster systems serve one copy per array).
#[test]
fn all_named_systems_measure_tiny_gcn() {
    use cgra_mem::exp::{measure_cell, ScenarioSpec, WorkloadRegistry};
    let reg = WorkloadRegistry::builtin();
    let scen = ScenarioSpec::preset("aggregate/tiny");
    for sys in builtin_systems().iter().chain(cgra_mem::exp::extra_systems().iter()) {
        let m = measure_cell(&reg, &scen, sys).unwrap_or_else(|e| panic!("{}: {e}", sys.name));
        assert!(m.time_us > 0.0, "{}", sys.name);
        assert!(m.output_ok, "{}", sys.name);
        assert_eq!(m.system, sys.name);
    }
}

/// The paper's CGRA ordering on the tiny kernel, with the ideal backend
/// as the floor: starved SPM-only > Cache+SPM > Runahead >= Ideal.
#[test]
fn cgra_systems_order_tiny_with_ideal_floor() {
    let wl = GcnAggregate::new(GraphSpec::tiny());
    let spm = run_workload(
        &wl,
        SubsystemConfig::spm_only(2, 4096),
        CgraConfig::hycube_4x4(ExecMode::Normal),
    );
    let cache = measure_spec(&wl, &SystemSpec::cache_spm());
    let ra = measure_spec(&wl, &SystemSpec::runahead());
    let ideal = measure_spec(&wl, &SystemSpec::ideal());
    assert!(spm.result.time_us() > cache.time_us);
    assert!(cache.time_us > ra.time_us);
    assert!(ra.cycles >= ideal.cycles, "no real system may beat the ceiling");
}

/// Banked DRAM acceptance ordering: with the L2 removed (every miss pays
/// the channel), a bank-conflict-heavy irregular gather slows down versus
/// the flat-latency channel, while a streaming kernel does not regress.
#[test]
fn banked_dram_slows_irregular_gather_but_not_streaming() {
    use cgra_mem::sim::{AluOp, CgraArray, DfgBuilder, Geometry, Mapper};
    let banked = DramModelKind::Banked(BankedDramConfig::paper_default());
    let geom = Geometry { rows: 4, cols: 4, ports: 2, hop_budget: 3 };
    let no_l2 = |dram: DramModelKind| {
        let mut c = SubsystemConfig::paper_base();
        c.l2 = cgra_mem::mem::CacheConfig { sets: 1, ways: 0, line_bytes: 64, vline_shift: 0 };
        c.dram = dram;
        c
    };
    // Irregular: a 64-iteration random gather over 256 KB — the indices
    // are SPM-resident, every gathered line is a scattered DRAM fetch
    // landing on an already-open different row (bank conflict).
    let gather_n = 64u64;
    let run_gather = |dram: DramModelKind| {
        let mut b = DfgBuilder::new("gather");
        let i = b.iter_idx();
        let idx = b.array_load(0, 0x0000, i); // SPM-resident index array
        let v = b.array_load(1, 0x40000, idx);
        b.array_store(1, 0x1000, i, v); // port1 SPM window
        let dfg = b.finish();
        let mapping = Mapper::new(geom).map(&dfg).unwrap();
        let mut mem = cgra_mem::mem::MemorySubsystem::new(no_l2(dram), 1 << 20);
        mem.place_spm(0, 0x0000);
        mem.place_spm(1, 0x1000);
        let mut x = 7u32;
        for k in 0..gather_n as u32 {
            x = x.wrapping_mul(1664525).wrapping_add(1013904223);
            let idx = x % 65536; // 64 K words = 256 KB
            mem.backing.write_u32(k * 4, idx);
            mem.backing.write_u32(0x40000 + idx * 4, k);
        }
        let mut arr = CgraArray::new(CgraConfig::hycube_4x4(ExecMode::Normal), dfg, mapping);
        arr.run(&mut mem, gather_n)
    };
    let flat_gather = run_gather(DramModelKind::Flat);
    let banked_gather = run_gather(banked);
    assert!(
        banked_gather.cycles > flat_gather.cycles,
        "irregular gather must pay bank conflicts: banked {} vs flat {}",
        banked_gather.cycles,
        flat_gather.cycles
    );
    assert!(banked_gather.mem.dram_row_conflicts > banked_gather.mem.dram_row_hits);
    assert_eq!(flat_gather.mem.dram_row_conflicts, 0);

    // Streaming: sequential vecadd; the three arrays sit in three distinct
    // rows on three distinct banks, so after one activate per array the
    // whole stream rides open rows.
    let stream_n = 256u64;
    let run_stream = |dram: DramModelKind| {
        let mut b = DfgBuilder::new("vecadd");
        let i = b.iter_idx();
        let av = b.array_load(0, 0x10000, i); // row 32 -> bank 0
        let bv = b.array_load(1, 0x20800, i); // row 65 -> bank 1
        let s = b.alu(AluOp::Add, av, bv);
        b.array_store(0, 0x31000, i, s); // row 98 -> bank 2
        let dfg = b.finish();
        let mapping = Mapper::new(geom).map(&dfg).unwrap();
        let mut mem = cgra_mem::mem::MemorySubsystem::new(no_l2(dram), 1 << 20);
        mem.place_spm(0, 0x0000);
        mem.place_spm(1, 0x1000);
        for k in 0..stream_n as u32 {
            mem.backing.write_u32(0x10000 + k * 4, k);
            mem.backing.write_u32(0x20800 + k * 4, 2 * k);
        }
        let mut arr = CgraArray::new(CgraConfig::hycube_4x4(ExecMode::Normal), dfg, mapping);
        arr.run(&mut mem, stream_n)
    };
    let flat_stream = run_stream(DramModelKind::Flat);
    let banked_stream = run_stream(banked);
    assert!(
        banked_stream.cycles <= flat_stream.cycles,
        "streaming must not regress: banked {} vs flat {}",
        banked_stream.cycles,
        flat_stream.cycles
    );
    assert!(banked_stream.mem.dram_row_hits > banked_stream.mem.dram_row_conflicts);
}

/// The full small suite stays correct on the banked channel and on the
/// ideal backend, in both execution modes.
#[test]
fn small_suite_correct_on_new_backends() {
    let mut banked = SubsystemConfig::paper_base();
    banked.dram = DramModelKind::Banked(BankedDramConfig::paper_default());
    let ideal = MemoryModelSpec::Ideal(cgra_mem::mem::IdealConfig::with_ports(2));
    for wl in small_suite() {
        for mode in [ExecMode::Normal, ExecMode::Runahead] {
            let b = run_workload(wl.as_ref(), banked, CgraConfig::hycube_4x4(mode));
            assert!(b.output_ok, "{} banked {:?}", wl.name(), mode);
            let i = run_workload_model(wl.as_ref(), &ideal, CgraConfig::hycube_4x4(mode));
            assert!(i.output_ok, "{} ideal {:?}", wl.name(), mode);
            assert_eq!(i.result.stall_cycles, 0, "{} ideal never stalls", wl.name());
        }
    }
}

/// The online reconfiguration loop preserves correctness on every small
/// kernel — the closed loop now fires *during* the run on the 8×8
/// Reconfig system (the retired `reconfig_experiment` ran it offline).
#[test]
fn online_reconfig_loop_preserves_correctness() {
    let mut cgra = CgraConfig::hycube_8x8(ExecMode::Normal);
    cgra.reconfig = ReconfigPolicy::online();
    for wl in small_suite().into_iter().take(4) {
        let run = run_workload(wl.as_ref(), SubsystemConfig::paper_reconfig(), cgra);
        assert!(run.output_ok, "{}", wl.name());
    }
}

/// Satellite regression for the old fig17 bug: the plan must be *gated on
/// the monitor trigger* — a run whose L1s never cross the miss-rate
/// threshold applies zero plans and keeps its geometry — and when plans
/// do apply, their flush/migration cost lands in-band (asserted exactly
/// in the sim-layer `epoch_hook_cost_is_charged_in_band` test; here we
/// assert the end-to-end ledger).
#[test]
fn reconfig_application_is_gated_on_the_monitor_trigger() {
    // Near-perfectly-cacheable stream: a tiny 64-word working set plus
    // sequential idx/out streams (~1 miss per 16 line accesses, so a
    // windowed miss rate around 5%). At a 35% threshold the monitor
    // never comes close, so online reconfig must do nothing at all.
    let quiet = PhasedGather::new(4096, 4096, 64, 3); // single streaming phase
    let mut cgra = CgraConfig::hycube_4x4(ExecMode::Normal);
    cgra.reconfig = ReconfigPolicy::online();
    cgra.reconfig.threshold = 0.35;
    let run = run_workload(&quiet, SubsystemConfig::paper_base(), cgra);
    assert!(run.output_ok);
    assert_eq!(
        run.reconfig_applies, 0,
        "a healthy cache must never trigger a replan (ways moved: {})",
        run.reconfig_ways_moved
    );
    // A sensitive policy on the genuinely phase-alternating gather (whose
    // random phases push the windowed miss rate way up) does fire.
    let phased = PhasedGather::small();
    let mut cgra = CgraConfig::hycube_4x4(ExecMode::Normal);
    cgra.reconfig = ReconfigPolicy::online();
    cgra.reconfig.threshold = 0.02;
    let run = run_workload(&phased, SubsystemConfig::paper_base(), cgra);
    assert!(run.output_ok);
    assert!(run.reconfig_applies > 0, "the phased gather must trigger the monitor");
}

/// Acceptance (adaptivity): on the phase-alternating gather, online
/// reconfiguration beats the static profile-once-and-lock protocol —
/// static keeps the first triggering phase's plan and loses every other
/// phase; online re-plans at the boundaries (paying its flush cost
/// in-band) and keeps both phases fast.
#[test]
fn online_reconfig_beats_static_on_phased_gather() {
    let wl = PhasedGather::small();
    let measure = |mode: ReconfigMode| {
        let mut cgra = CgraConfig::hycube_4x4(ExecMode::Normal);
        cgra.reconfig = match mode {
            ReconfigMode::Off => ReconfigPolicy::off(),
            ReconfigMode::Static => ReconfigPolicy::adapt_static(),
            ReconfigMode::Online => ReconfigPolicy::online(),
        };
        // Sensitive trigger: both phases cross it, so static locks the
        // plan of whichever phase its first window sampled while online
        // keeps re-planning.
        cgra.reconfig.threshold = 0.02;
        run_workload(&wl, SubsystemConfig::paper_base(), cgra)
    };
    let stat = measure(ReconfigMode::Static);
    let online = measure(ReconfigMode::Online);
    assert!(stat.output_ok && online.output_ok);
    assert!(stat.reconfig_applies <= 1, "static is one-shot");
    assert!(online.reconfig_applies >= 2, "online must re-plan across phases");
    assert!(
        online.result.cycles < stat.result.cycles,
        "online must beat static on phase-alternating access: online {} vs static {}",
        online.result.cycles,
        stat.result.cycles
    );
}

/// MSHR-starved configurations still complete and validate (structural
/// stall path).
#[test]
fn mshr_starved_system_still_correct() {
    let mut cfg = SubsystemConfig::paper_base();
    cfg.mshr_entries = 1;
    cfg.store_buffer_entries = 1;
    for wl in small_suite().into_iter().take(3) {
        for mode in [ExecMode::Normal, ExecMode::Runahead] {
            let run = run_workload(wl.as_ref(), cfg, CgraConfig::hycube_4x4(mode));
            assert!(run.output_ok, "{} {:?}", wl.name(), mode);
        }
    }
}

/// Tiny single-entry caches (worst-case thrash) still validate.
#[test]
fn degenerate_cache_geometry_still_correct() {
    let mut cfg = SubsystemConfig::paper_base();
    cfg.l1 = cgra_mem::mem::CacheConfig { sets: 1, ways: 1, line_bytes: 16, vline_shift: 0 };
    for wl in small_suite().into_iter().take(3) {
        let run = run_workload(wl.as_ref(), cfg, CgraConfig::hycube_4x4(ExecMode::Runahead));
        assert!(run.output_ok, "{}", wl.name());
    }
}

/// Acceptance: the fig11a five-system campaign reproduces through the new
/// Engine/ExperimentSpec API with the paper's system ordering
/// SPM-starved < Cache+SPM < Runahead (execution time, lower is faster).
/// Restricted to the tiny graph so the test stays fast; the full-size
/// campaign is `repro figure fig11a`. The tiny graph fits the 133 KB SPM
/// entirely, so the SPM-only slot is swapped for a capacity-starved SPM,
/// as in Fig 2.
#[test]
fn engine_reproduces_fig11a_system_ordering() {
    use cgra_mem::exp::{Engine, ExperimentSpec, SystemSpec};
    let starved = SystemSpec::spm_starved(4096);
    let starved_name = starved.name.clone();
    let spec = ExperimentSpec::fig11a()
        .workloads(["aggregate/tiny"])
        .replace_system("SPM-only", starved);
    let engine = Engine::new(2);
    let report = engine.run(&spec);
    assert_eq!(report.measurements.len(), 6); // five systems + ideal ceiling
    assert!(report.measurements.iter().all(|m| m.output_ok));
    let t = |sys: &str| report.time_of("aggregate/tiny", sys).unwrap();
    assert!(t(&starved_name) > t("Cache+SPM"), "SPM-starved must be slowest CGRA");
    assert!(t("Cache+SPM") > t("Runahead"), "runahead must win");
    assert!(t("Runahead") >= t("Ideal"), "the ceiling is a floor on time");
    // Same engine pool serves a follow-up spec (persistent workers).
    let again = engine.run(&ExperimentSpec::new("again")
        .workload("aggregate/tiny")
        .system(SystemSpec::runahead()));
    assert_eq!(again.cycles_of("aggregate/tiny", "Runahead"),
               report.cycles_of("aggregate/tiny", "Runahead"));
}

/// Acceptance (irregular families): at working sets beyond the caches,
/// hash-join probe and unstructured-mesh SpMV are memory-bound under
/// Cache+SPM — utilization collapses versus the ideal-latency ceiling —
/// and runahead recovers part of the gap.
#[test]
fn join_and_mesh_are_memory_bound_and_runahead_recovers() {
    // skew 0 keeps every probe a cold gather; the random mesh order
    // scatters the x gathers across 36 KB per port.
    let join = HashJoin::probe_phase(8192, 32768, 16384, 0.0, 91);
    let mesh = MeshSpmv::new(96, MeshOrder::Random, 101);
    for wl in [&join as &dyn Workload, &mesh as &dyn Workload] {
        let cache = measure_spec(wl, &SystemSpec::cache_spm());
        let ra = measure_spec(wl, &SystemSpec::runahead());
        let ideal = measure_spec(wl, &SystemSpec::ideal());
        assert!(cache.output_ok && ra.output_ok && ideal.output_ok, "{}", wl.name());
        assert!(
            cache.cycles > 2 * ideal.cycles,
            "{} must be memory-bound: cache {} vs ideal {}",
            wl.name(),
            cache.cycles,
            ideal.cycles
        );
        assert!(
            cache.utilization < ideal.utilization,
            "{} utilization must collapse under Cache+SPM",
            wl.name()
        );
        assert!(
            ra.cycles < cache.cycles,
            "{} runahead must win: ra {} vs cache {}",
            wl.name(),
            ra.cycles,
            cache.cycles
        );
    }
}

/// Acceptance (scenario layer): a sweep spec with parameterized workload
/// entries (mesh size × system) parses strictly, runs through the Engine,
/// and the working-set-scaling figure renders over the same seam.
#[test]
fn param_sweep_spec_runs_end_to_end_and_scaling_figure_renders() {
    use cgra_mem::exp::{Engine, ExperimentSpec, Json};
    let text = r#"{
        "name": "mesh-scaling",
        "workloads": [
            {"family": "mesh", "name": "mesh/8",  "dim": 8,  "order": "random"},
            {"family": "mesh", "name": "mesh/12", "dim": 12, "order": "random"},
            {"family": "join", "name": "join-tiny", "phase": "probe",
             "rows": 64, "buckets": 256, "probes": 512, "skew": 0.5}
        ],
        "systems": [{"base": "Cache+SPM"}, {"base": "Ideal"}]
    }"#;
    let spec = ExperimentSpec::from_json(&Json::parse(text).unwrap()).unwrap();
    let engine = Engine::new(2);
    let report = engine.run(&spec);
    assert_eq!(report.workloads, vec!["mesh/8", "mesh/12", "join-tiny"]);
    assert_eq!(report.measurements.len(), 6);
    assert!(report.measurements.iter().all(|m| m.output_ok));
    // Larger mesh, more cycles — the params really reached the workload.
    assert!(
        report.cycles_of("mesh/12", "Cache+SPM").unwrap()
            > report.cycles_of("mesh/8", "Cache+SPM").unwrap()
    );

    // Strictness: a typoed param key is a hard error naming the key...
    let bad = r#"{"workloads": [{"family": "mesh", "dims": 8}],
                  "systems": [{"base": "Cache+SPM"}]}"#;
    let spec = ExperimentSpec::from_json(&Json::parse(bad).unwrap()).unwrap();
    let e = engine.try_run(&spec).unwrap_err();
    assert!(e.contains("dims"), "{e}");
    // ...and a misspelled preset suggests the nearest name.
    let bad = r#"{"workloads": ["small/meshh"], "systems": [{"base": "Cache+SPM"}]}"#;
    let spec = ExperimentSpec::from_json(&Json::parse(bad).unwrap()).unwrap();
    let e = engine.try_run(&spec).unwrap_err();
    assert!(e.contains("small/mesh"), "{e}");

    // The scaling figure runs over the same parameterized seam.
    let session = engine.session();
    let fig = cgra_mem::report::scaling_with(&session, &[8, 12]);
    assert!(fig.contains("mesh/8x8") && fig.contains("mesh/12x12"), "{fig}");
    assert!(fig.contains("SPM-only") && fig.contains("Ideal"), "{fig}");
}

/// Scenario determinism: the same spec JSON (workload params + seed)
/// yields byte-identical report JSON across independent engines.
#[test]
fn same_spec_json_runs_to_byte_identical_reports() {
    use cgra_mem::exp::{Engine, ExperimentSpec, Json};
    let text = r#"{
        "name": "det",
        "workloads": [
            "small/join_build",
            {"family": "mesh", "dim": 10, "order": "random", "seed": 7}
        ],
        "systems": [{"base": "Cache+SPM"}, {"base": "Runahead"}]
    }"#;
    let render = || {
        let spec = ExperimentSpec::from_json(&Json::parse(text).unwrap()).unwrap();
        Engine::new(2).run(&spec).to_json().render_pretty()
    };
    let a = render();
    let b = render();
    assert_eq!(a, b, "identical specs must produce identical report bytes");
}

/// Determinism (online reconfiguration): the closed loop is part of the
/// simulated machine — monitor, planner and in-band flush cost included —
/// so an online-reconfig sweep run twice from the same spec JSON produces
/// byte-identical reports.
#[test]
fn online_reconfig_sweep_is_byte_identical_across_runs() {
    use cgra_mem::exp::{Engine, ExperimentSpec, Json};
    let text = r#"{
        "name": "online-det",
        "workloads": [
            {"family": "phased", "n": 1024, "period": 128, "span": 1024}
        ],
        "systems": [
            {"base": "Cache+SPM", "name": "off"},
            {"base": "Cache+SPM", "name": "static", "reconfig": "static",
             "reconfig_threshold": 0.02},
            {"base": "Cache+SPM", "name": "online", "reconfig": "online",
             "reconfig_period": 512, "reconfig_threshold": 0.02, "reconfig_window": 256}
        ]
    }"#;
    let render = || {
        let spec = ExperimentSpec::from_json(&Json::parse(text).unwrap()).unwrap();
        Engine::new(2).run(&spec).to_json().render_pretty()
    };
    let a = render();
    let b = render();
    assert_eq!(a, b, "online reconfiguration must be deterministic");
}

/// Acceptance (warm store): fig17 — the last formerly-uncached figure —
/// now renders through session cells: a warm-store re-run performs zero
/// simulations and reproduces the figure text byte for byte. The new
/// adaptivity figure rides the same seam.
#[test]
fn warm_store_fig17_and_adaptivity_render_with_zero_simulations() {
    use cgra_mem::exp::{Engine, ResultStore};
    let path = std::env::temp_dir()
        .join(format!("cgra-itest-cellstore-{}-fig17.jsonl", std::process::id()));
    let _ = ResultStore::clear(&path);
    let names = vec!["aggregate/tiny".to_string(), "small/rgb".to_string()];

    let eng = Engine::new(2);
    let cold = eng.session_with_store(ResultStore::open(&path).unwrap());
    let cold_fig17 = cgra_mem::report::fig17_with(&cold, &names);
    let cold_adapt = cgra_mem::report::adaptivity_with(&cold, 1024, 1024, &[128]);
    assert!(cold.stats().executed > 0);
    drop(cold);

    let eng2 = Engine::new(3);
    let warm = eng2.session_with_store(ResultStore::open(&path).unwrap());
    let warm_fig17 = cgra_mem::report::fig17_with(&warm, &names);
    let warm_adapt = cgra_mem::report::adaptivity_with(&warm, 1024, 1024, &[128]);
    assert_eq!(warm.stats().executed, 0, "warm store must satisfy every reconfig cell");
    assert_eq!(warm_fig17, cold_fig17, "fig17 must replay byte-identically");
    assert_eq!(warm_adapt, cold_adapt, "adaptivity must replay byte-identically");
    let _ = ResultStore::clear(&path);
}

/// Acceptance (trace engine): the committed 22-point cache-geometry
/// sweep over one captured scenario performs exactly one DFG simulation
/// (the capture pre-pass, which doubles as the source row's cell), and
/// every replayed point's memory columns are byte-identical to a live
/// simulation of the same geometry.
#[test]
fn replay_geometry_sweep_runs_one_simulation_and_matches_live() {
    use cgra_mem::exp::{Engine, ExperimentSpec, Json, ResultStore};
    let text = std::fs::read_to_string("specs/replay_geometry.json")
        .expect("specs/replay_geometry.json is committed");
    let spec = ExperimentSpec::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert!(spec.systems.len() >= 21, "spec must carry >= 20 replay points");

    // Fresh store + trace dir: the cold-run count below must not be
    // satisfied by leftovers from an earlier test run.
    let dir = std::env::temp_dir().join(format!("cgra-itest-replaygeo-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let eng = Engine::new(4);
    let session = eng.session_with_store(ResultStore::open(dir.join("cells.jsonl")).unwrap());
    let report = session.run(&spec);
    let st = session.stats();
    assert_eq!(st.executed, 1, "exactly one DFG simulation: {st:?}");
    assert_eq!(st.replays as usize, spec.systems.len() - 1, "{st:?}");

    // The identical-geometry replay point reproduces the live source row.
    let live_src = report.get("aggregate/tiny", "Cache+SPM").unwrap();
    let same = report.get("aggregate/tiny", "r-l1.4k-w4-l2.128k").unwrap();
    assert_eq!(same.cycles, live_src.cycles);
    assert_eq!(same.stall_cycles, live_src.stall_cycles);
    assert_eq!(same.l1_accesses, live_src.l1_accesses);
    assert_eq!(same.l1_hits, live_src.l1_hits);

    // Spot-check swept geometries against genuinely live simulations.
    let live_text = r#"{
        "name": "replay-geometry-live",
        "workloads": ["aggregate/tiny"],
        "systems": [
            {"base": "Cache+SPM", "name": "live-a", "l1_bytes": 2048,  "l1_ways": 2, "l2_bytes": 65536},
            {"base": "Cache+SPM", "name": "live-b", "l1_bytes": 8192,  "l1_ways": 8, "l2_bytes": 131072},
            {"base": "Cache+SPM", "name": "live-c", "l1_bytes": 16384, "l1_ways": 4, "l2_bytes": 65536}
        ]
    }"#;
    let live_spec = ExperimentSpec::from_json(&Json::parse(live_text).unwrap()).unwrap();
    let live = Engine::new(2).run(&live_spec);
    for (replayed, lived) in [
        ("r-l1.2k-w2-l2.64k", "live-a"),
        ("r-l1.8k-w8-l2.128k", "live-b"),
        ("r-l1.16k-w4-l2.64k", "live-c"),
    ] {
        let r = report.get("aggregate/tiny", replayed).unwrap();
        let l = live.get("aggregate/tiny", lived).unwrap();
        for (col, a, b) in [
            ("cycles", r.cycles, l.cycles),
            ("stall_cycles", r.stall_cycles, l.stall_cycles),
            ("spm_accesses", r.spm_accesses, l.spm_accesses),
            ("l1_accesses", r.l1_accesses, l.l1_accesses),
            ("l1_hits", r.l1_hits, l.l1_hits),
            ("l2_accesses", r.l2_accesses, l.l2_accesses),
            ("dram_accesses", r.dram_accesses, l.dram_accesses),
        ] {
            assert_eq!(a, b, "{col} diverged on {replayed} vs {lived}");
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Acceptance (session layer): overlapping campaigns submitted to one
/// session — the `repro all` shape, where Fig 13/15/16 all re-plot
/// Runahead cells — execute each unique (scenario, system, repeat) cell
/// exactly once; everything else is served from the session table.
#[test]
fn overlapping_campaigns_execute_each_unique_cell_exactly_once() {
    use cgra_mem::exp::{Engine, ExperimentSpec, SystemSpec};
    let eng = Engine::new(2);
    let session = eng.session();
    let workloads = ["aggregate/tiny", "small/rgb", "small/mesh"];
    // fig13 shape: suite × {Cache+SPM, Runahead, Ideal}.
    let a = session.run(&ExperimentSpec::new("f13").workloads(workloads).systems([
        SystemSpec::cache_spm(),
        SystemSpec::runahead(),
        SystemSpec::ideal(),
    ]));
    // fig15/fig16 shape: suite × Runahead — fully contained in the above.
    let b = session
        .run(&ExperimentSpec::new("f15").workloads(workloads).system(SystemSpec::runahead()));
    let c = session
        .run(&ExperimentSpec::new("f16").workloads(workloads).system(SystemSpec::runahead()));
    let st = session.stats();
    assert_eq!(st.cells_requested, (workloads.len() * 3 + workloads.len() * 2) as u64);
    assert_eq!(st.executed, (workloads.len() * 3) as u64, "each unique cell simulates once");
    assert_eq!(st.session_hits, (workloads.len() * 2) as u64);
    assert_eq!(st.store_hits, 0);
    // The shared cells carry identical measurements under every job.
    for w in &workloads {
        assert_eq!(a.cycles_of(w, "Runahead"), b.cycles_of(w, "Runahead"));
        assert_eq!(b.cycles_of(w, "Runahead"), c.cycles_of(w, "Runahead"));
    }
    assert!(a.measurements.iter().all(|m| m.output_ok));
}

/// Acceptance (result store): a second session against a warm store
/// performs zero simulations while emitting byte-identical report JSON
/// and byte-identical figure text.
#[test]
fn warm_store_rerun_is_byte_identical_with_zero_simulations() {
    use cgra_mem::exp::{Engine, ExperimentSpec, ResultStore, SystemSpec};
    let path = std::env::temp_dir().join(format!(
        "cgra-itest-cellstore-{}-warmrerun.jsonl",
        std::process::id()
    ));
    let _ = ResultStore::clear(&path);
    let spec = ExperimentSpec::new("warm")
        .workloads(["aggregate/tiny", "small/join_probe"])
        .systems([SystemSpec::cache_spm(), SystemSpec::runahead()]);

    // Cold run: everything simulates, everything persists.
    let eng = Engine::new(2);
    let cold = eng.session_with_store(ResultStore::open(&path).unwrap());
    let cold_report = cold.run(&spec);
    let cold_fig = cgra_mem::report::scaling_with(&cold, &[8]);
    let cold_stats = cold.stats();
    assert_eq!(cold_stats.store_hits, 0);
    assert!(cold_stats.executed > 0);
    drop(cold);

    // Warm run in a fresh engine (a new process, as far as the store is
    // concerned): zero simulations, identical bytes.
    let eng2 = Engine::new(3);
    let warm = eng2.session_with_store(ResultStore::open(&path).unwrap());
    let warm_report = warm.run(&spec);
    assert_eq!(warm.stats().executed, 0, "warm store must satisfy every cell");
    assert_eq!(warm.stats().store_hits, spec.workloads.len() as u64 * 2);
    assert_eq!(
        warm_report.to_json().render_pretty(),
        cold_report.to_json().render_pretty(),
        "cached re-run must reproduce the report byte for byte"
    );
    let warm_fig = cgra_mem::report::scaling_with(&warm, &[8]);
    assert_eq!(warm.stats().executed, 0, "the figure must also be served from the store");
    assert_eq!(warm_fig, cold_fig, "figure text must be byte-identical on a warm store");
    let _ = ResultStore::clear(&path);
}

/// Acceptance (migration): flattening a sharded store back into the v1
/// single-file layout and re-opening it adopts every line into shards,
/// and a warm re-run replays every cell with zero simulations and a
/// byte-identical report.
#[test]
fn legacy_single_file_store_migrates_to_shards_byte_identically() {
    use cgra_mem::exp::{Engine, ExperimentSpec, ResultStore, SystemSpec};
    let pid = std::process::id();
    let root = std::env::temp_dir().join(format!("cgra-itest-cellstore-{pid}-migrate"));
    let legacy = std::env::temp_dir().join(format!("cgra-itest-cellstore-{pid}-legacy"));
    let _ = ResultStore::clear(&root);
    let _ = ResultStore::clear(&legacy);
    let spec = ExperimentSpec::new("migrate")
        .workloads(["aggregate/tiny", "small/join_probe"])
        .systems([SystemSpec::cache_spm(), SystemSpec::runahead()]);

    // Cold run against a sharded store.
    let eng = Engine::new(2);
    let cold = eng.session_with_store(ResultStore::open(&root).unwrap());
    let cold_report = cold.run(&spec);
    assert!(cold.stats().executed > 0);
    drop(cold);

    // Flatten every shard line into one v1-style single file.
    let mut lines = String::new();
    for entry in std::fs::read_dir(&root).unwrap() {
        let p = entry.unwrap().path();
        if p.extension().and_then(|e| e.to_str()) == Some("jsonl") {
            lines.push_str(&std::fs::read_to_string(&p).unwrap());
        }
    }
    assert!(!lines.is_empty(), "the cold run must have persisted shard lines");
    std::fs::write(&legacy, &lines).unwrap();

    // Opening the legacy path adopts the single file into shards; a
    // warm run then replays every cell without simulating.
    let eng2 = Engine::new(3);
    let warm = eng2.session_with_store(ResultStore::open(&legacy).unwrap());
    let warm_report = warm.run(&spec);
    assert_eq!(warm.stats().executed, 0, "the migrated store must satisfy every cell");
    assert_eq!(
        warm_report.to_json().render_pretty(),
        cold_report.to_json().render_pretty(),
        "migration must preserve every cell byte for byte"
    );
    assert!(
        std::fs::metadata(&legacy).unwrap().is_dir(),
        "the legacy single file is replaced by a shard directory"
    );
    let _ = ResultStore::clear(&root);
    let _ = ResultStore::clear(&legacy);
}

/// Acceptance (concurrency): two sessions running disjoint halves of one
/// spec against the same store directory — concurrently, each with its
/// own store handle, like two `repro sweep --jobs-from` processes — leave
/// a merged store that satisfies a warm full run with zero simulations
/// and a report byte-identical to an uncached cold run.
#[test]
fn two_sessions_splitting_one_spec_merge_into_one_warm_store() {
    use cgra_mem::exp::{Engine, ExperimentSpec, ResultStore, SystemSpec};
    let root =
        std::env::temp_dir().join(format!("cgra-itest-cellstore-{}-split", std::process::id()));
    let _ = ResultStore::clear(&root);
    let full = || {
        ExperimentSpec::new("split")
            .workloads(["aggregate/tiny", "small/rgb", "small/join_probe", "small/mesh"])
            .systems([SystemSpec::cache_spm(), SystemSpec::runahead()])
    };
    // Uncached reference for the byte-identity check.
    let reference = Engine::new(2).session().run(&full());

    let halves: Vec<_> = (0..2usize)
        .map(|k| {
            let root = root.clone();
            std::thread::spawn(move || {
                let mut spec = full();
                spec.workloads = spec
                    .workloads
                    .into_iter()
                    .enumerate()
                    .filter(|(i, _)| i % 2 == k)
                    .map(|(_, w)| w)
                    .collect();
                let eng = Engine::new(1);
                let session = eng.session_with_store(ResultStore::open(&root).unwrap());
                session.run(&spec);
                session.stats().executed
            })
        })
        .collect();
    for h in halves {
        assert!(h.join().expect("half-sweep thread") > 0, "each half simulates its slice");
    }

    let eng = Engine::new(2);
    let warm = eng.session_with_store(ResultStore::open(&root).unwrap());
    let warm_report = warm.run(&full());
    assert_eq!(warm.stats().executed, 0, "the merged store must satisfy the full spec");
    assert_eq!(warm.stats().store_hits, 8);
    assert_eq!(
        warm_report.to_json().render_pretty(),
        reference.to_json().render_pretty(),
        "split halves must merge into the same report an uncached run produces"
    );
    let _ = ResultStore::clear(&root);
}

/// Satellite (contention): two arrays hammering the shared banked-DRAM
/// channel pay measurably more total cycles than twice the solo run —
/// the shared L2 halves each array's effective capacity and the
/// interleaved gather streams close each other's DRAM rows. The shared
/// levels attribute the interference per array (cross-array row-buffer
/// conflicts, per-array L1 traffic). Ideal-backend clusters, whose
/// slots are fully private, scale linearly instead: N arrays serve N
/// copies in exactly the makespan one array needs for one copy.
#[test]
fn shared_channel_contention_slows_cluster_but_ideal_scales_linearly() {
    use cgra_mem::sim::{Cluster, ClusterJob, ClusterSpec, SchedulerKind};
    let mut banked = SubsystemConfig::paper_base();
    banked.dram = DramModelKind::Banked(BankedDramConfig::paper_default());
    let serve = |mem: &MemoryModelSpec, arrays: usize| {
        let jobs: Vec<ClusterJob> = (0..arrays)
            .map(|_| ClusterJob {
                workload: Box::new(PhasedGather::small()),
                family: "phased".to_string(),
            })
            .collect();
        let mut c = Cluster::new(ClusterSpec { arrays, scheduler: SchedulerKind::Fifo }, mem);
        c.run(CgraConfig::hycube_4x4(ExecMode::Runahead), &jobs)
    };

    let hier = MemoryModelSpec::Hierarchy(banked);
    let solo = serve(&hier, 1);
    let duo = serve(&hier, 2);
    assert!(solo.all_outputs_ok() && duo.all_outputs_ok());
    let solo_lat = solo.jobs[0].latency();
    let duo_total: u64 = duo.jobs.iter().map(|j| j.latency()).sum();
    assert!(
        duo_total > 2 * solo_lat,
        "two arrays on the shared channel must pay contention: {duo_total} total vs 2x{solo_lat}"
    );
    assert!(duo.makespan > solo.makespan);
    // Attribution: the slowdown shows up as cross-array row-buffer
    // interference, a counter a single-array run cannot accumulate.
    assert!(duo.channel.xarray_conflicts > 0, "shared rows must record cross-array closes");
    assert_eq!(solo.channel.xarray_conflicts, 0);
    assert!(duo.arrays.iter().all(|a| a.stats.l1_accesses > 0 && a.l1_miss_rate() > 0.0));

    let ideal = MemoryModelSpec::Ideal(cgra_mem::mem::IdealConfig::with_ports(2));
    let solo_i = serve(&ideal, 1);
    let quad_i = serve(&ideal, 4);
    assert!(solo_i.all_outputs_ok() && quad_i.all_outputs_ok());
    assert_eq!(
        quad_i.makespan, solo_i.makespan,
        "private ideal slots must scale linearly (no shared level to contend on)"
    );
}

/// Acceptance (scheduling): on a skewed serving mix, locality-aware
/// dispatch beats FIFO end to end through the cell front door — the
/// config loads it skips and the L1 state it keeps warm shorten the
/// serving makespan.
#[test]
fn locality_beats_fifo_on_a_skewed_mix() {
    use cgra_mem::exp::{measure_cell, ScenarioSpec, SystemSpec, WorkloadRegistry};
    use cgra_mem::sim::{ClusterSpec, SchedulerKind};
    let reg = WorkloadRegistry::builtin();
    let mix = ScenarioSpec::mix(24, 0.6, 7).named("mix-skewed");
    let sys = |k: SchedulerKind| {
        SystemSpec::cluster_model(
            format!("Cluster-2x-{}", k.name()),
            MemoryModelSpec::Hierarchy(SubsystemConfig::paper_base()),
            CgraConfig::hycube_4x4(ExecMode::Runahead),
            ClusterSpec { arrays: 2, scheduler: k },
        )
    };
    let fifo = measure_cell(&reg, &mix, &sys(SchedulerKind::Fifo)).unwrap();
    let loc = measure_cell(&reg, &mix, &sys(SchedulerKind::Locality)).unwrap();
    assert!(fifo.output_ok && loc.output_ok);
    assert_eq!(fifo.cluster_jobs, 24);
    assert_eq!(loc.cluster_jobs, 24);
    assert!(
        loc.cycles < fifo.cycles,
        "locality dispatch must shorten the serving run (locality {} vs fifo {})",
        loc.cycles,
        fifo.cycles
    );
}

/// Satellite (reconfig × cluster): each clustered array carries its own
/// online-reconfiguration controller — cooldown and miss-rate windows
/// are per-array state. Two arrays serving the phase-alternating gather
/// must each re-plan across phases exactly like the solo run does; a
/// shared controller's cooldown would swallow one array's phase
/// boundaries whenever the other fires first.
#[test]
fn online_reconfig_state_is_per_array_in_a_cluster() {
    use cgra_mem::sim::{Cluster, ClusterJob, ClusterSpec, SchedulerKind};
    let mut cgra = CgraConfig::hycube_4x4(ExecMode::Normal);
    cgra.reconfig = ReconfigPolicy::online();
    cgra.reconfig.threshold = 0.02;
    let serve = |arrays: usize| {
        let jobs: Vec<ClusterJob> = (0..arrays)
            .map(|_| ClusterJob {
                workload: Box::new(PhasedGather::small()),
                family: "phased".to_string(),
            })
            .collect();
        let mut c = Cluster::new(
            ClusterSpec { arrays, scheduler: SchedulerKind::Fifo },
            &MemoryModelSpec::Hierarchy(SubsystemConfig::paper_base()),
        );
        c.run(cgra, &jobs)
    };
    let solo = serve(1);
    assert!(solo.all_outputs_ok());
    assert!(
        solo.arrays[0].reconfig_applies >= 2,
        "the cluster path must preserve the solo online-reconfig behavior"
    );
    let duo = serve(2);
    assert!(duo.all_outputs_ok());
    for (i, a) in duo.arrays.iter().enumerate() {
        assert!(
            a.reconfig_applies >= 2,
            "array {i} must re-plan across both phases independently (applies = {})",
            a.reconfig_applies
        );
        assert!(a.reconfig_ways_moved > 0, "array {i} moved no ways");
    }
    // Identical jobs on symmetric slots: private controllers behave
    // alike (the shared L2/channel skews timing, not the per-array
    // miss-rate windows that drive the monitor).
    let (a0, a1) = (duo.arrays[0].reconfig_applies, duo.arrays[1].reconfig_applies);
    assert!(
        a0.abs_diff(a1) <= 1,
        "per-array controllers on identical jobs must behave alike ({a0} vs {a1})"
    );
}

/// Satellite (store): cluster cells are content-addressed like solo
/// cells — a second session over a warm store serves the identical
/// cluster sweep (mix scenario × cluster systems) with zero simulations
/// and byte-identical report JSON.
#[test]
fn cluster_cells_warm_replay_with_zero_simulations() {
    use cgra_mem::exp::{Engine, ExperimentSpec, ResultStore, ScenarioSpec, SystemSpec};
    let path = std::env::temp_dir()
        .join(format!("cgra-itest-cellstore-{}-cluster.jsonl", std::process::id()));
    let _ = ResultStore::clear(&path);
    let spec = ExperimentSpec::new("cluster-warm")
        .workload(ScenarioSpec::mix(6, 0.6, 7).named("mix"))
        .systems([SystemSpec::cluster_runahead(2), SystemSpec::cluster_locality()]);

    let eng = Engine::new(2);
    let cold = eng.session_with_store(ResultStore::open(&path).unwrap());
    let cold_report = cold.run(&spec);
    assert_eq!(cold.stats().executed, 2, "one serving run per cluster system");
    assert!(cold_report.measurements.iter().all(|m| m.output_ok && m.cluster_jobs == 6));
    drop(cold);

    let eng2 = Engine::new(3);
    let warm = eng2.session_with_store(ResultStore::open(&path).unwrap());
    let warm_report = warm.run(&spec);
    assert_eq!(warm.stats().executed, 0, "a warm store must simulate zero cluster cells");
    assert_eq!(warm.stats().store_hits, 2);
    assert_eq!(
        warm_report.to_json().render_pretty(),
        cold_report.to_json().render_pretty(),
        "cluster cells must replay byte-identically"
    );
    let _ = ResultStore::clear(&path);
}

/// The cluster figures render through the session seam (smoke-sized
/// sweep) with every (arrays × scheduler) cell present.
#[test]
fn cluster_figures_render_at_smoke_sizes() {
    use cgra_mem::exp::Engine;
    let eng = Engine::new(2);
    let session = eng.session();
    let thr = cgra_mem::report::cluster_throughput_with(&session, &[1, 2], 6, 0.6, 7);
    assert!(
        thr.contains("fifo") && thr.contains("sjf") && thr.contains("locality"),
        "{thr}"
    );
    let lat = cgra_mem::report::cluster_latency_with(&session, &[1, 2], &[0.2, 0.8], 6, 7);
    assert!(lat.contains("p50") && lat.contains("p99"), "{lat}");
}

/// A JSON sweep spec (the `repro sweep` path) round-trips end to end:
/// parse spec → run → emit report → parse report.
#[test]
fn json_sweep_spec_runs_and_report_round_trips() {
    use cgra_mem::exp::{Engine, ExperimentSpec, Json, Report};
    let text = r#"{
        "name": "it-sweep",
        "workloads": ["aggregate/tiny"],
        "systems": [
            {"base": "Cache+SPM"},
            {"base": "Cache+SPM", "name": "Cache+SPM 2-way", "l1_ways": 2},
            {"base": "Runahead", "name": "Runahead-8x8", "geometry": "8x8"}
        ]
    }"#;
    let spec = ExperimentSpec::from_json(&Json::parse(text).unwrap()).unwrap();
    let report = Engine::new(2).run(&spec);
    assert_eq!(report.systems, vec!["Cache+SPM", "Cache+SPM 2-way", "Runahead-8x8"]);
    assert!(report.measurements.iter().all(|m| m.output_ok));
    let back = Report::from_json(&Json::parse(&report.to_json().render_pretty()).unwrap()).unwrap();
    assert_eq!(back, report);
}
