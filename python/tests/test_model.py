"""L2 model correctness: the GCN layer (fwd + bwd) against its reference
composition, plus shape checks at the artifact contract sizes."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _inputs(rng, e, n, f):
    src = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    dst = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    w = jnp.asarray(rng.uniform(0.25, 0.75, e), jnp.float32)
    feat = jnp.asarray(rng.normal(size=(n, f)), jnp.float32)
    dense_w = jnp.asarray(rng.normal(size=(f, f)) * 0.1, jnp.float32)
    bias = jnp.asarray(rng.normal(size=f) * 0.1, jnp.float32)
    return src, dst, w, feat, dense_w, bias


def test_layer_matches_reference():
    rng = np.random.default_rng(11)
    args = _inputs(rng, 512, 64, 8)
    got = model.gcn_layer(*args)
    want = ref.gcn_layer_ref(*args)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_layer_output_shape_and_relu():
    rng = np.random.default_rng(13)
    args = _inputs(rng, 256, 32, 4)
    out = model.gcn_layer(*args)
    assert out.shape == (32, 4)
    assert float(out.min()) >= 0.0


def test_grad_shapes_and_finiteness():
    rng = np.random.default_rng(17)
    args = _inputs(rng, 256, 32, 4)
    g_feat, g_w, g_b = model.gcn_layer_grad(*args)
    assert g_feat.shape == (32, 4)
    assert g_w.shape == (4, 4)
    assert g_b.shape == (4,)
    for g in (g_feat, g_w, g_b):
        assert bool(jnp.isfinite(g).all())


def test_grad_matches_reference_autodiff():
    rng = np.random.default_rng(19)
    args = _inputs(rng, 512, 64, 8)

    def loss_ref(feat, dense_w, bias):
        out = ref.gcn_layer_ref(args[0], args[1], args[2], feat, dense_w, bias)
        return 0.5 * jnp.sum(out * out)

    want = jax.grad(loss_ref, argnums=(0, 1, 2))(args[3], args[4], args[5])
    got = model.gcn_layer_grad(*args)
    for g, wgt in zip(got, want):
        np.testing.assert_allclose(g, wgt, rtol=1e-4, atol=1e-5)


def test_tiny_contract_shapes_lower():
    """The artifact contract shapes (aot.TINY) trace without error."""
    from compile import aot

    g = aot.TINY
    rng = np.random.default_rng(23)
    args = _inputs(rng, g["edges"], g["nodes"], g["feat"])
    out = model.gcn_layer(*args)
    assert out.shape == (g["nodes"], g["feat"])
