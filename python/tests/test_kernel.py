"""L1 correctness: Pallas kernels vs pure-jnp oracles (the CORE build-time
correctness signal), swept over shapes/data with hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.aggregate import aggregate, _aggregate_pallas, vmem_footprint_bytes
from compile.kernels.gather import face_gather
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _graph(rng, e, n, f):
    src = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    dst = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    w = jnp.asarray(rng.uniform(0.25, 0.75, e), jnp.float32)
    feat = jnp.asarray(rng.normal(size=(n, f)), jnp.float32)
    return src, dst, w, feat


class TestAggregate:
    @settings(max_examples=20, deadline=None)
    @given(
        e=st.sampled_from([1, 7, 64, 512, 1024]),
        n=st.sampled_from([1, 16, 256]),
        f=st.sampled_from([1, 4, 16]),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref_over_shapes(self, e, n, f, seed):
        rng = np.random.default_rng(seed)
        src, dst, w, feat = _graph(rng, e, n, f)
        got = _aggregate_pallas(src, dst, w, feat)
        want = ref.aggregate_ref(src, dst, w, feat)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_multi_tile_grid(self):
        # E = 2048 = 4 tiles of 512: accumulation must carry across grid steps.
        rng = np.random.default_rng(3)
        src, dst, w, feat = _graph(rng, 2048, 64, 8)
        got = _aggregate_pallas(src, dst, w, feat)
        want = ref.aggregate_ref(src, dst, w, feat)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_duplicate_sources_accumulate(self):
        # All edges write the same output row.
        e, n, f = 64, 8, 4
        src = jnp.zeros(e, jnp.int32)
        dst = jnp.asarray(np.arange(e) % n, jnp.int32)
        w = jnp.ones(e, jnp.float32)
        feat = jnp.ones((n, f), jnp.float32)
        got = _aggregate_pallas(src, dst, w, feat)
        assert float(got[0, 0]) == pytest.approx(e)
        assert float(jnp.abs(got[1:]).sum()) == 0.0

    def test_empty_feature_contribution_is_zero_rows(self):
        rng = np.random.default_rng(5)
        src, dst, w, feat = _graph(rng, 16, 64, 4)
        got = _aggregate_pallas(src, dst, w, feat)
        touched = set(np.asarray(src).tolist())
        for row in range(64):
            if row not in touched:
                assert float(jnp.abs(got[row]).sum()) == 0.0

    def test_vjp_matches_autodiff_of_ref(self):
        rng = np.random.default_rng(7)
        src, dst, w, feat = _graph(rng, 256, 32, 4)

        def loss_kernel(w_, feat_):
            return 0.5 * jnp.sum(aggregate(src, dst, w_, feat_) ** 2)

        def loss_ref(w_, feat_):
            return 0.5 * jnp.sum(ref.aggregate_ref(src, dst, w_, feat_) ** 2)

        gk_w, gk_f = jax.grad(loss_kernel, argnums=(0, 1))(w, feat)
        gr_w, gr_f = jax.grad(loss_ref, argnums=(0, 1))(w, feat)
        np.testing.assert_allclose(gk_w, gr_w, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(gk_f, gr_f, rtol=1e-4, atol=1e-5)

    def test_vmem_footprint_estimate_reasonable(self):
        # 512-edge tile on tiny shapes stays far under a TPU core's ~16 MiB.
        assert vmem_footprint_bytes(512, 256, 4) < 16 * 1024 * 1024


class TestFaceGather:
    @settings(max_examples=20, deadline=None)
    @given(
        faces=st.sampled_from([1, 33, 512, 1024]),
        cells=st.sampled_from([1, 64, 512]),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref_over_shapes(self, faces, cells, seed):
        rng = np.random.default_rng(seed)
        own = jnp.asarray(rng.integers(0, cells, faces), jnp.int32)
        nei = jnp.asarray(rng.integers(0, cells, faces), jnp.int32)
        coef = jnp.asarray(rng.uniform(0.1, 0.9, faces), jnp.float32)
        phi = jnp.asarray(rng.normal(size=cells), jnp.float32)
        got = face_gather(own, nei, coef, phi)
        want = ref.face_gather_ref(own, nei, coef, phi)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_self_face_is_zero(self):
        own = jnp.asarray([3, 5], jnp.int32)
        got = face_gather(own, own, jnp.ones(2), jnp.arange(8, dtype=jnp.float32))
        np.testing.assert_allclose(got, jnp.zeros(2))
