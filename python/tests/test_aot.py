"""AOT path checks: every artifact lowers to parseable HLO text with the
expected entry signature, and the lowered modules stay Mosaic-free (the
CPU PJRT client cannot execute Mosaic custom-calls)."""

import jax

from compile import aot

jax.config.update("jax_platform_name", "cpu")


def test_all_artifacts_lower():
    arts = aot.lower_all()
    assert set(arts) == {"aggregate", "aggregate_cora", "gather", "gcn_layer", "gcn_layer_grad"}
    for name, text in arts.items():
        assert "ENTRY" in text, name
        assert len(text) > 200, name


def test_no_mosaic_custom_calls():
    for name, text in aot.lower_all().items():
        assert "tpu_custom_call" not in text, f"{name} lowered to Mosaic"
        assert "mosaic" not in text.lower(), f"{name} lowered to Mosaic"


def test_artifact_is_deterministic():
    a = aot.lower_all()["aggregate"]
    b = aot.lower_all()["aggregate"]
    assert a == b
