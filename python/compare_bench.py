#!/usr/bin/env python3
"""Gate a fresh BENCH_sim.json against the committed baseline.

Usage:
    python3 compare_bench.py BASELINE FRESH [--tolerance 0.20]
    python3 compare_bench.py BASELINE FRESH --refresh

The gated column is ``sim_cycles`` — it is deterministic and
machine-independent, so a drift beyond the tolerance means the simulator's
timing behaviour changed (intentional changes should refresh the baseline
in the same PR). Wall-clock columns (``wall_s`` / ``iters_per_sec``) are
machine-dependent and reported for information only. ``output_ok`` must be
true in every fresh row regardless of the baseline.

A baseline with ``"bootstrap": true`` (or no rows) passes with a notice:
it marks a trajectory that has not been seeded from a real run yet.
``--refresh`` copies the fresh result over the baseline (dropping the
bootstrap marker) — run it on a toolchain machine and commit the result.
"""

import argparse
import json
import shutil
import sys


def load(path):
    with open(path) as f:
        return json.load(f)


def rows_by_cell(doc):
    return {(r["kernel"], r["system"]): r for r in doc.get("rows", [])}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline")
    ap.add_argument("fresh")
    ap.add_argument("--tolerance", type=float, default=0.20,
                    help="allowed relative drift in sim_cycles (default 0.20)")
    ap.add_argument("--refresh", action="store_true",
                    help="overwrite BASELINE with FRESH instead of comparing")
    args = ap.parse_args()

    if args.refresh:
        shutil.copyfile(args.fresh, args.baseline)
        print(f"baseline refreshed from {args.fresh} -> {args.baseline}")
        return 0

    fresh = load(args.fresh)
    fresh_rows = rows_by_cell(fresh)
    failures = []

    for (k, s), row in sorted(fresh_rows.items()):
        if not row.get("output_ok", False):
            failures.append(f"{k} x {s}: output_ok is false")

    baseline = load(args.baseline)
    if baseline.get("bootstrap") or not baseline.get("rows"):
        print("NOTICE: baseline is a bootstrap marker (no seeded rows).")
        print("Seed it from a real run and commit:")
        print(f"  python3 compare_bench.py {args.baseline} {args.fresh} --refresh")
        for f in failures:
            print(f"FAIL {f}")
        return 1 if failures else 0

    base_rows = rows_by_cell(baseline)
    for cell, base in sorted(base_rows.items()):
        k, s = cell
        row = fresh_rows.get(cell)
        if row is None:
            failures.append(f"{k} x {s}: present in baseline, missing from fresh run")
            continue
        b, f = base["sim_cycles"], row["sim_cycles"]
        drift = abs(f - b) / max(b, 1)
        status = "FAIL" if drift > args.tolerance else "ok"
        print(f"{status:>4} {k:<22} {s:<14} cycles {b:>12} -> {f:>12} "
              f"({drift * 100:+.1f}% vs ±{args.tolerance * 100:.0f}%) "
              f"[{row.get('iters_per_sec', 0):.0f} iters/s, informational]")
        if drift > args.tolerance:
            failures.append(
                f"{k} x {s}: sim_cycles {b} -> {f} drifts {drift * 100:.1f}% "
                f"(> {args.tolerance * 100:.0f}%)")
    for cell in sorted(set(fresh_rows) - set(base_rows)):
        print(f"note {cell[0]} x {cell[1]}: new cell, not in baseline (refresh to adopt)")

    if failures:
        print(f"\n{len(failures)} failure(s):")
        for f in failures:
            print(f"  - {f}")
        print("If the drift is intentional, refresh and commit the baseline:")
        print(f"  python3 compare_bench.py {args.baseline} {args.fresh} --refresh")
        return 1
    print("\nbench within tolerance of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
