"""L2 JAX model: one GCN layer (the application the paper's motivating
kernel comes from — PyTorch-Geometric's GCN, §4) built on the L1 Pallas
aggregation kernel, plus its backward pass.

Python only runs at build time: `aot.py` lowers these functions to HLO
text that the rust runtime loads through PJRT.
"""

import jax
import jax.numpy as jnp

from .kernels.aggregate import aggregate


def gcn_layer(src, dst, w, feat, dense_w, bias):
    """h = aggregate(feat); out = relu(h @ W + b).

    The aggregation is the Pallas kernel; the dense transform lowers to a
    plain XLA dot so the whole layer fuses into one HLO module.
    """
    h = aggregate(src, dst, w, feat)
    return jnp.maximum(h @ dense_w + bias, 0.0)


def gcn_layer_loss(src, dst, w, feat, dense_w, bias):
    """Scalar training loss (½‖out‖²) — differentiable surrogate used to
    exercise the backward path."""
    out = gcn_layer(src, dst, w, feat, dense_w, bias)
    return 0.5 * jnp.sum(out * out)


def gcn_layer_grad(src, dst, w, feat, dense_w, bias):
    """Gradients of the loss w.r.t. (feature table, dense weights, bias).

    The aggregate kernel is linear in `feat`, so its VJP lowers to the
    transposed gather/scatter; everything stays inside one HLO module.
    """
    return jax.grad(gcn_layer_loss, argnums=(3, 4, 5))(src, dst, w, feat, dense_w, bias)
