"""AOT compile path: lower the L2/L1 functions to HLO *text* artifacts the
rust runtime loads via PJRT.

HLO text — NOT ``lowered.compile()`` / serialized protos — is the
interchange format: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction
ids which the xla crate's xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifact shapes mirror the rust-side synthetic datasets exactly
(``rust/src/workloads/graphs.rs``): the `tiny` graph drives the end-to-end
numeric cross-check in examples/gcn_pipeline.rs; the `cora`-shaped module
is the deployment-scale artifact.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import gcn_layer, gcn_layer_grad
from .kernels.aggregate import aggregate
from .kernels.gather import face_gather

# Shape contracts with rust/src/workloads/graphs.rs (GraphSpec::tiny and
# the grad kernel's small variant).
TINY = dict(nodes=256, edges=1024, feat=4)
CORA = dict(nodes=2708, edges=10556, feat=16)
GRAD_SMALL = dict(cells=512, faces=2048)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _graph_specs(g):
    i32 = lambda n: jax.ShapeDtypeStruct((n,), jnp.int32)
    f32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)
    return (
        i32(g["edges"]),  # src
        i32(g["edges"]),  # dst
        f32(g["edges"]),  # w
        f32(g["nodes"], g["feat"]),  # feat
    )


def lower_all():
    """Return {artifact name: HLO text}."""
    arts = {}

    # Plain aggregation kernels (tiny for the cross-check, cora-scale).
    for name, g in [("aggregate", TINY), ("aggregate_cora", CORA)]:
        lowered = jax.jit(lambda s, d, w, f: (aggregate(s, d, w, f),)).lower(*_graph_specs(g))
        arts[name] = to_hlo_text(lowered)

    # grad-style face gather.
    gs = GRAD_SMALL
    i32 = lambda n: jax.ShapeDtypeStruct((n,), jnp.int32)
    f32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)
    lowered = jax.jit(lambda o, n, c, p: (face_gather(o, n, c, p),)).lower(
        i32(gs["faces"]), i32(gs["faces"]), f32(gs["faces"]), f32(gs["cells"])
    )
    arts["gather"] = to_hlo_text(lowered)

    # Full GCN layer forward + backward (tiny shapes, hidden dim = feat).
    g = TINY
    specs = _graph_specs(g) + (
        f32(g["feat"], g["feat"]),  # dense W
        f32(g["feat"]),  # bias
    )
    lowered = jax.jit(lambda *a: (gcn_layer(*a),)).lower(*specs)
    arts["gcn_layer"] = to_hlo_text(lowered)
    lowered = jax.jit(lambda *a: tuple(gcn_layer_grad(*a))).lower(*specs)
    arts["gcn_layer_grad"] = to_hlo_text(lowered)

    return arts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    for name, text in lower_all().items():
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")


if __name__ == "__main__":
    main()
