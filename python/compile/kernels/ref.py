"""Pure-jnp oracles for the Pallas kernels — the build-time correctness
signal (pytest asserts kernel == ref before any artifact ships)."""

import jax.numpy as jnp


def aggregate_ref(src, dst, w, feat):
    """out[src[e]] += w[e] * feat[dst[e]] via scatter-add."""
    n, _f = feat.shape
    contrib = w[:, None] * feat[dst]
    out = jnp.zeros((n, feat.shape[1]), feat.dtype)
    return out.at[src].add(contrib)


def face_gather_ref(own, nei, coef, phi):
    """out[i] = coef[i] * (phi[nei[i]] - phi[own[i]])."""
    return coef * (phi[nei] - phi[own])


def gcn_layer_ref(src, dst, w, feat, dense_w, bias):
    """Aggregate → dense → ReLU (reference composition)."""
    h = aggregate_ref(src, dst, w, feat)
    return jnp.maximum(h @ dense_w + bias, 0.0)
