"""L1 Pallas kernel: GCN feature aggregation (the paper's Listing 1).

    for e in range(E):
        out[edge_start[e]] += weight[e] * feature[edge_end[e]]

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper separates
the *regular* edge streams from the *irregular* feature gather with an
SPM-vs-cache split; on TPU the same insight maps to keeping the edge tile
in VMEM (BlockSpec-scheduled) while rows of ``feature``/``out`` are
gathered/scattered per edge. The kernel is written at edge-tile
granularity: the grid walks edge tiles; each step gathers/accumulates its
tile's contribution. ``interpret=True`` everywhere — the CPU PJRT plugin
cannot execute Mosaic custom-calls (see /opt/xla-example/README.md), and
correctness is what the AOT path needs; TPU-roofline notes live in
EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Edges processed per grid step (VMEM tile of 3 x TILE_E x 4 bytes).
TILE_E = 512


def _aggregate_kernel(src_ref, dst_ref, w_ref, feat_ref, out_ref, *, tile_e: int):
    """One grid step: accumulate `tile_e` edges into the full output.

    The output block is the whole (N, F) array for every step, so the
    accumulation carries across grid steps (revisiting semantics).
    """
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    def body(i, _):
        s = src_ref[i]
        d = dst_ref[i]
        wv = w_ref[i]
        row = pl.load(feat_ref, (d, slice(None)))
        cur = pl.load(out_ref, (s, slice(None)))
        pl.store(out_ref, (s, slice(None)), cur + wv * row)
        return 0

    jax.lax.fori_loop(0, tile_e, body, 0)


def _aggregate_pallas(src, dst, w, feat):
    """Pallas edge-parallel aggregation. Shapes: src/dst/w (E,), feat (N,F).

    E must be a multiple of TILE_E or small enough for one tile.
    """
    e = src.shape[0]
    n, f = feat.shape
    tile = TILE_E if e % TILE_E == 0 else e
    grid = e // tile
    kernel = functools.partial(_aggregate_kernel, tile_e=tile)
    return pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),  # src tile in VMEM
            pl.BlockSpec((tile,), lambda i: (i,)),  # dst tile
            pl.BlockSpec((tile,), lambda i: (i,)),  # weight tile
            pl.BlockSpec((n, f), lambda i: (0, 0)),  # full feature table
        ],
        out_specs=pl.BlockSpec((n, f), lambda i: (0, 0)),  # revisited accumulator
        out_shape=jax.ShapeDtypeStruct((n, f), feat.dtype),
        interpret=True,
    )(src, dst, w, feat)


@jax.custom_vjp
def aggregate(src, dst, w, feat):
    """Differentiable wrapper. The kernel is linear in `w` and `feat`, so
    its VJP is the transposed gather/scatter pair (pure XLA ops — they fuse
    into the same HLO module as the forward Pallas body)."""
    return _aggregate_pallas(src, dst, w, feat)


def _aggregate_fwd(src, dst, w, feat):
    return _aggregate_pallas(src, dst, w, feat), (src, dst, w, feat)


def _aggregate_bwd(res, ct):
    import numpy as np

    src, dst, w, feat = res
    g_w = jnp.sum(ct[src] * feat[dst], axis=1)
    g_feat = jnp.zeros_like(feat).at[dst].add(w[:, None] * ct[src])
    f0 = lambda x: np.zeros(x.shape, jax.dtypes.float0)  # int args: no cotangent
    return (f0(src), f0(dst), g_w, g_feat)


aggregate.defvjp(_aggregate_fwd, _aggregate_bwd)


def vmem_footprint_bytes(e_tile: int, n: int, f: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM residency of one grid step (edge tiles + the
    gathered tables). Used by the §Perf roofline notes — interpret=True
    gives no real TPU timing."""
    edge_tiles = 3 * e_tile * dtype_bytes
    tables = 2 * n * f * dtype_bytes  # feat + out blocks
    return edge_tiles + tables
