"""L1 Pallas kernel: coefficient-weighted irregular gather — the OpenFOAM
``grad`` access structure (Table 1):

    out[i] = coef[i] * (phi[nei[i]] - phi[own[i]])

Same VMEM schedule as the aggregate kernel: index/coefficient tiles are
regular (BlockSpec-tiled), the ``phi`` table is gathered irregularly.
``interpret=True`` for CPU-PJRT executability.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 512


def _gather_kernel(own_ref, nei_ref, coef_ref, phi_ref, out_ref, *, tile: int):
    def body(i, _):
        o = own_ref[i]
        n = nei_ref[i]
        c = coef_ref[i]
        diff = pl.load(phi_ref, (n,)) - pl.load(phi_ref, (o,))
        pl.store(out_ref, (i,), c * diff)
        return 0

    jax.lax.fori_loop(0, tile, body, 0)


@jax.jit
def face_gather(own, nei, coef, phi):
    """Per-face gather-difference. Shapes: own/nei/coef (FACES,), phi (N,)."""
    faces = own.shape[0]
    n = phi.shape[0]
    tile = TILE if faces % TILE == 0 else faces
    grid = faces // tile
    kernel = functools.partial(_gather_kernel, tile=tile)
    return pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((faces,), phi.dtype),
        interpret=True,
    )(own, nei, coef, phi)
